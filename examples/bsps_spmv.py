"""BSPS sparse matrix-vector multiplication — the paper's §7 future work.

"We have some preliminary work on sparse matrix vector multiplication …
within the BSPS model." This example realises it: the sparse matrix (CSR,
padded to fixed-nnz row blocks — ELL-style tokens so every token has the
paper's constant size C_i) streams from external memory; the dense vector x
is the *resident* data structure in local memory; each hyperstep multiplies
one row-block token into the output. Arithmetic intensity is ~2 FLOPs per
streamed word, so the BSPS cost model predicts bandwidth-heavy hypersteps on
every machine with e > 1 — validated against the runner's own
``predicted_vs_measured()`` row: the run executes through
``HyperstepRunner(plan=host_plan(...), machine=...)`` like train/serve do,
not a hand-rolled loop.

Run: PYTHONPATH=src python examples/bsps_spmv.py [n] [density]
"""

import sys

import jax.numpy as jnp
import numpy as np

from repro.core import HyperstepRunner, StreamSet, host_plan
from repro.core.calibrate import calibrate


def make_ell_blocks(n: int, density: float, block_rows: int, seed: int = 0):
    """Random sparse matrix as ELL row-block tokens (cols, vals) + dense x."""
    rng = np.random.default_rng(seed)
    nnz_per_row = max(1, int(n * density))
    cols = rng.integers(0, n, (n, nnz_per_row), dtype=np.int32)
    vals = rng.standard_normal((n, nnz_per_row)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    nb = n // block_rows
    return (cols.reshape(nb, block_rows, nnz_per_row),
            vals.reshape(nb, block_rows, nnz_per_row), x)


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 14
    density = float(sys.argv[2]) if len(sys.argv) > 2 else 0.01
    block_rows = 512
    cols, vals, x = make_ell_blocks(n, density, block_rows)
    nb, _, nnz = cols.shape

    ss = StreamSet()
    sc = ss.create(cols, 1, name="cols")
    sv = ss.create(vals, 1, name="vals")
    xd = jnp.asarray(x)                          # resident vector (local mem)

    acc = calibrate()
    plan = host_plan(
        [sc, sv],
        # one multiply-add per stored nonzero of the row block
        flops_per_hyperstep=2.0 * block_rows * nnz,
        name=f"spmv_n{n}",
    )
    runner = HyperstepRunner(
        lambda acc_, toks: acc_
        + [np.asarray(jnp.einsum("rj,rj->r", jnp.asarray(toks[1][0]),
                                 xd[jnp.asarray(toks[0][0])]))],
        [sc, sv], device=None, plan=plan, machine=acc,
    )
    parts = runner.run([])
    y = np.concatenate(parts)

    # dense reference
    ref = np.zeros(n, np.float32)
    flat_c, flat_v = cols.reshape(n, nnz), vals.reshape(n, nnz)
    for j in range(nnz):
        ref += flat_v[:, j] * x[flat_c[:, j]]
    err = float(np.abs(y - ref).max())

    row = runner.predicted_vs_measured()
    regime = "bandwidth" if row["bandwidth_heavy_predicted"] else "compute"
    print(f"spmv n={n} nnz/row={nnz} blocks={nb}: err={err:.2e} "
          f"measured={row['measured_seconds'] * 1e3:.1f}ms "
          f"predicted={row['predicted_seconds'] * 1e3:.1f}ms | "
          f"model says {regime}-heavy (e={acc.e:.1f}) | "
          f"fetch words planned={row['fetch_words_planned']:.0f} "
          f"measured={row['fetch_words_measured']:.0f}")
    comp = np.median([r.compute_seconds for r in runner.records[:-1]])
    fetch = np.median([r.fetch_seconds for r in runner.records[:-1]])
    print(f"measured per-hyperstep: compute {comp * 1e3:.2f}ms "
          f"fetch {fetch * 1e3:.2f}ms -> "
          f"{'bandwidth' if fetch > comp else 'compute'}-heavy "
          f"(measured vote: "
          f"{'bandwidth' if row['bandwidth_heavy_measured'] else 'compute'})")


if __name__ == "__main__":
    main()
