"""BSPS sparse matrix-vector multiplication — the paper's §7 future work.

"We have some preliminary work on sparse matrix vector multiplication …
within the BSPS model." This example realises it: the sparse matrix (CSR,
padded to fixed-nnz row blocks — ELL-style tokens so every token has the
paper's constant size C_i) streams from external memory; the dense vector x
is the *resident* data structure in local memory; each hyperstep multiplies
one row-block token into the output. Arithmetic intensity is ~2 FLOPs per
streamed word, so the BSPS cost model predicts bandwidth-heavy hypersteps on
every machine with e > 1 — checked against measured timings below.

Run: PYTHONPATH=src python examples/bsps_spmv.py [n] [density]
"""

import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.calibrate import calibrate
from repro.core import HyperstepCost, HyperstepRunner, StreamSet


def make_ell_blocks(n: int, density: float, block_rows: int, seed: int = 0):
    """Random sparse matrix as ELL row-block tokens (cols, vals) + dense x."""
    rng = np.random.default_rng(seed)
    nnz_per_row = max(1, int(n * density))
    cols = rng.integers(0, n, (n, nnz_per_row), dtype=np.int32)
    vals = rng.standard_normal((n, nnz_per_row)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    nb = n // block_rows
    return (cols.reshape(nb, block_rows, nnz_per_row),
            vals.reshape(nb, block_rows, nnz_per_row), x)


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 14
    density = float(sys.argv[2]) if len(sys.argv) > 2 else 0.01
    block_rows = 512
    cols, vals, x = make_ell_blocks(n, density, block_rows)
    nb, _, nnz = cols.shape

    ss = StreamSet()
    sc = ss.create(cols, 1, name="cols")
    sv = ss.create(vals, 1, name="vals")
    xd = jnp.asarray(x)                          # resident vector (local mem)

    runner = HyperstepRunner(
        lambda acc, toks: acc
        + [np.asarray(jnp.einsum("rj,rj->r", jnp.asarray(toks[1][0]),
                                 xd[jnp.asarray(toks[0][0])]))],
        [sc, sv], device=None,
    )
    t0 = time.perf_counter()
    parts = runner.run([])
    elapsed = time.perf_counter() - t0
    y = np.concatenate(parts)

    # dense reference
    ref = np.zeros(n, np.float32)
    flat_c, flat_v = cols.reshape(n, nnz), vals.reshape(n, nnz)
    for j in range(nnz):
        ref += flat_v[:, j] * x[flat_c[:, j]]
    err = float(np.abs(y - ref).max())

    # BSPS cost: per hyperstep C = 2·block_rows·nnz words, 2·block_rows·nnz flops
    acc = calibrate()
    c_words = 2 * block_rows * nnz
    h = HyperstepCost(bsp_flops=2 * block_rows * nnz, fetch_words=[c_words])
    regime = "bandwidth" if h.bandwidth_heavy(acc) else "compute"
    pred = acc.flops_to_seconds(nb * (h.cost(acc) + acc.l))
    print(f"spmv n={n} nnz/row={nnz} blocks={nb}: err={err:.2e} "
          f"measured={elapsed * 1e3:.1f}ms predicted={pred * 1e3:.1f}ms | "
          f"model says {regime}-heavy (e={acc.e:.1f})")
    comp = np.median([r.compute_seconds for r in runner.records[:-1]])
    fetch = np.median([r.fetch_seconds for r in runner.records[:-1]])
    print(f"measured per-hyperstep: compute {comp * 1e3:.2f}ms "
          f"fetch {fetch * 1e3:.2f}ms -> "
          f"{'bandwidth' if fetch > comp else 'compute'}-heavy")


if __name__ == "__main__":
    main()
