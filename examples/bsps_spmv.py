"""BSPS sparse matrix-vector multiplication — the paper's §7 future work.

"We have some preliminary work on sparse matrix vector multiplication …
within the BSPS model." This example realises it: the sparse matrix (CSR,
padded to fixed-nnz row blocks — ELL-style tokens so every token has the
paper's constant size C_i) streams from external memory; the dense vector x
is the *resident* data structure in local memory; each hyperstep multiplies
one row-block token into a y-block that streams back *up*. Arithmetic
intensity is ~2 FLOPs per streamed word, so the BSPS cost model predicts
bandwidth-heavy hypersteps on every machine with e > 1.

The run executes through ``HyperstepRunner(plan=host_plan(...), machine=...)``
in both execution modes (DESIGN.md §5): the **compiled** single-dispatch scan
(production; prints hypersteps/sec) and the instrumented **measure** host
loop, whose per-hyperstep compute/fetch records validate the
bandwidth-vs-compute classification.

Run: PYTHONPATH=src python examples/bsps_spmv.py [n] [density]
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HyperstepRunner, StreamSet, host_plan
from repro.core.calibrate import calibrate


def make_ell_blocks(n: int, density: float, block_rows: int, seed: int = 0):
    """Random sparse matrix as ELL row-block tokens (cols, vals) + dense x."""
    rng = np.random.default_rng(seed)
    nnz_per_row = max(1, int(n * density))
    cols = rng.integers(0, n, (n, nnz_per_row), dtype=np.int32)
    vals = rng.standard_normal((n, nnz_per_row)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    nb = n // block_rows
    return (cols.reshape(nb, block_rows, nnz_per_row),
            vals.reshape(nb, block_rows, nnz_per_row), x)


def make_spmv_runner(cols, vals, x, acc=None):
    """(runner, y_stream, state0): one y row-block streams up per hyperstep."""
    nb, block_rows, nnz = cols.shape
    ss = StreamSet()
    sc = ss.create(cols, 1, name="cols")
    sv = ss.create(vals, 1, name="vals")
    sy = ss.create(np.zeros((nb, block_rows), np.float32), 1, name="y")
    xd = jnp.asarray(x)                          # resident vector (local mem)

    # jitted so a host-loop hyperstep pays one dispatch (the l the model
    # charges), not an op-by-op eager walk; the DMA lane stages the tokens
    # on device (device=...), so the host->device copy is fetch time, not
    # compute time — same split the cost model prices
    kernel = jax.jit(
        lambda c, v: jnp.einsum("rj,rj->r", v, xd[c]))

    def step(state, toks):
        return state, [kernel(jnp.asarray(toks[0][0]),
                              jnp.asarray(toks[1][0]))]

    plan = host_plan(
        [sc, sv], out_streams=[sy],
        # one multiply-add per stored nonzero of the row block
        flops_per_hyperstep=2.0 * block_rows * nnz,
        name=f"spmv_n{cols.shape[0] * block_rows}",
    )
    runner = HyperstepRunner(step, [sc, sv], out_streams=[sy],
                             device=jax.devices()[0], plan=plan, machine=acc)
    # state is donated by the compiled dispatch: make a fresh one per run
    return runner, sy, (lambda: jnp.int32(0))


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 14
    density = float(sys.argv[2]) if len(sys.argv) > 2 else 0.01
    block_rows = 512
    cols, vals, x = make_ell_blocks(n, density, block_rows)
    nb, _, nnz = cols.shape
    acc = calibrate()

    # -- compiled mode: the whole pass is one device dispatch ----------------
    runner, sy, state0 = make_spmv_runner(cols, vals, x, acc)
    runner.run(state0(), compiled=True)          # warm up (traces the scan)
    runner.reset_records()
    t0 = time.perf_counter()
    runner.run(state0(), compiled=True)
    compiled_s = time.perf_counter() - t0
    y = np.asarray(sy.data).reshape(n)

    # dense reference
    ref = np.zeros(n, np.float32)
    flat_c, flat_v = cols.reshape(n, nnz), vals.reshape(n, nnz)
    for j in range(nnz):
        ref += flat_v[:, j] * x[flat_c[:, j]]
    err = float(np.abs(y - ref).max())

    row = runner.predicted_vs_measured()
    regime = "bandwidth" if row["bandwidth_heavy_predicted"] else "compute"
    print(f"spmv n={n} nnz/row={nnz} blocks={nb}: err={err:.2e} "
          f"compiled={compiled_s * 1e3:.1f}ms "
          f"({nb / compiled_s:.0f} hypersteps/s, 1 dispatch) "
          f"predicted={row['predicted_seconds'] * 1e3:.1f}ms | "
          f"model says {regime}-heavy (e={acc.e:.1f}) | "
          f"fetch words planned={row['fetch_words_planned']:.0f} "
          f"measured={row['fetch_words_measured']:.0f}")

    # -- measure mode: per-hyperstep records validate the classification -----
    m_runner, m_sy, m_state0 = make_spmv_runner(cols, vals, x, acc)
    t0 = time.perf_counter()
    m_runner.run(m_state0())
    host_s = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(m_sy.data).reshape(n), y,
                               rtol=1e-5, atol=1e-5)
    mrow = m_runner.predicted_vs_measured()
    comp = np.median([r.compute_seconds for r in m_runner.records[:-1]])
    fetch = np.median([r.fetch_seconds for r in m_runner.records[:-1]])
    print(f"measured per-hyperstep (host loop, {host_s * 1e3:.1f}ms total, "
          f"{compiled_s and host_s / compiled_s:.1f}x slower than compiled): "
          f"compute {comp * 1e3:.2f}ms fetch {fetch * 1e3:.2f}ms -> "
          f"{'bandwidth' if fetch > comp else 'compute'}-heavy "
          f"(measured vote: "
          f"{'bandwidth' if mrow['bandwidth_heavy_measured'] else 'compute'})")


if __name__ == "__main__":
    main()
